"""Architecture registry: the 10 assigned configs (+ reduced smoke variants).

Every module defines ``CONFIG`` (the exact published config) and ``tiny()``
(a reduced same-family config for CPU smoke tests).  Select with
``--arch <id>`` in the launchers; ``get(name)`` / ``get_tiny(name)`` here.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "llama_3_2_vision_11b",
    "qwen2_7b",
    "starcoder2_15b",
    "qwen2_72b",
    "llama3_405b",
    "seamless_m4t_large_v2",
    "rwkv6_7b",
    "arctic_480b",
    "moonshot_v1_16b_a3b",
    "recurrentgemma_2b",
]

# canonical dashed names (as in the assignment) -> module ids
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({"llama-3.2-vision-11b": "llama_3_2_vision_11b",
                "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
                "seamless-m4t-large-v2": "seamless_m4t_large_v2"})


def _module(name: str):
    key = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{key}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_tiny(name: str) -> ModelConfig:
    return _module(name).tiny()


def all_configs():
    return {a: get(a) for a in ARCH_IDS}


# ----------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cells(include_skips: bool = False):
    """All (arch, shape) cells.  long_500k runs only for sub-quadratic archs
    (rwkv6, recurrentgemma) — the 8 full-attention skips are documented in
    DESIGN.md §Arch-applicability."""
    out = []
    for a in ARCH_IDS:
        cfg = get(a)
        for s in SHAPES.values():
            runnable = s.name != "long_500k" or cfg.subquadratic
            if runnable or include_skips:
                out.append((a, s.name, runnable))
    return out
