"""Train/serve step builders.

``make_train_step`` supports gradient-accumulation microbatching (the
accumulation loop is a lax.scan whose per-microbatch DP all-reduce XLA
overlaps with the next microbatch's compute — the overlap trick from
DESIGN §7; ``microbatches`` is a PATSMA-tunable).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamW
from .loss import make_loss_fn

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step"]


def _split_microbatches(batch: dict, n: int) -> dict:
    def sp(x):
        b = x.shape[0]
        if b % n:
            raise ValueError(f"batch {b} not divisible by microbatches {n}")
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(
    model,
    optimizer: AdamW,
    *,
    microbatches: int = 1,
    logits_chunk: int = 0,
    aux_weight: float = 0.01,
):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(model, aux_weight=aux_weight, logits_chunk=logits_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mb = _split_microbatches(batch, microbatches)

            def body(acc, mbatch):
                (l, m), g = grad_fn(params, mbatch)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(jnp.add, acc_g, g)
                return (acc_g, acc_l + l), m

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(body, (zero_g, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)
        params, opt_state, opt_metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return step


def make_prefill_step(model):
    def prefill(params, batch):
        hidden, states = model.prefill(params, batch)
        logits = model.logits(params, hidden[:, None])[:, 0]
        return logits, states

    return prefill


def make_decode_step(model):
    def decode(params, token, states, pos):
        return model.decode_step(params, token, states, pos)

    return decode
