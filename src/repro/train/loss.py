"""Cross-entropy loss: full and vocab-chunked (streaming logsumexp) paths.

The chunked path never materializes the (B, S, V) logits tensor — it scans
over vocab chunks of the LM head with an online-softmax accumulator.  For
V=128k–256k at 1M tokens this is the difference between ~0.5–2 TB of logits
and a (B, S, chunk) working set; ``logits_chunk`` is a PATSMA-tunable knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["xent_full", "xent_chunked", "make_loss_fn"]


def _label_logit(h, w, labels):
    """h: (B,S,D), w: (D,V), labels: (B,S) -> (B,S) fp32 logits at the labels."""
    wl = jnp.take(w, labels, axis=1)  # (D,B,S) gather of label columns
    return jnp.einsum("bsd,dbs->bs", h.astype(jnp.float32), wl.astype(jnp.float32))


def xent_full(h, w, labels, valid=None):
    """Standard CE over the full vocabulary.  Returns (mean_loss, n_tokens)."""
    logits = (h @ w.astype(h.dtype)).astype(jnp.float32)  # (B,S,V)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per_tok = lse - ll
    if valid is None:
        valid = jnp.ones_like(labels, jnp.float32)
    n = jnp.maximum(jnp.sum(valid), 1.0)
    return jnp.sum(per_tok * valid) / n, n


def xent_chunked(h, w, labels, valid=None, chunk: int = 8192):
    """Streaming-logsumexp CE over vocab chunks of the head weights.

    w is reshaped to (n_chunks, D, chunk) and scanned; the accumulator keeps
    the per-token running (max, sumexp)."""
    B, S, D = h.shape
    V = w.shape[1]
    if V % chunk:
        raise ValueError(f"vocab {V} not divisible by logits_chunk {chunk}")
    nc = V // chunk
    wc = w.reshape(D, nc, chunk).transpose(1, 0, 2)  # (nc, D, chunk)
    hf = h

    def body(carry, wck):
        m, s = carry
        lg = (hf @ wck.astype(hf.dtype)).astype(jnp.float32)  # (B,S,chunk)
        cm = jnp.max(lg, axis=-1)
        nm = jnp.maximum(m, cm)
        s = s * jnp.exp(m - nm) + jnp.sum(jnp.exp(lg - nm[..., None]), axis=-1)
        return (nm, s), None

    m0 = jnp.full((B, S), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    (m, s), _ = jax.lax.scan(body, (m0, s0), wc)
    lse = m + jnp.log(s)
    ll = _label_logit(h, w, labels)
    per_tok = lse - ll
    if valid is None:
        valid = jnp.ones_like(labels, jnp.float32)
    n = jnp.maximum(jnp.sum(valid), 1.0)
    return jnp.sum(per_tok * valid) / n, n


def make_loss_fn(model, aux_weight: float = 0.01, logits_chunk: int = 0):
    """(params, batch) -> (loss, metrics).  batch: tokens/labels (+ctx inputs).
    Labels >= vocab_size (pad) are masked out; the vocab-pad columns never
    receive labels so gradients there are exactly the softmax pull-down."""

    def loss_fn(params, batch):
        hidden, aux = model.forward(params, batch)
        w = model.head_weights(params)
        labels = batch["labels"]
        valid = (labels >= 0) & (labels < model.cfg.vocab_size)
        labels = jnp.clip(labels, 0, model.cfg.vocab_size - 1)
        if logits_chunk and w.shape[1] % logits_chunk == 0:
            ce, n = xent_chunked(hidden, w, labels, valid.astype(jnp.float32), logits_chunk)
        else:
            ce, n = xent_full(hidden, w, labels, valid.astype(jnp.float32))
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux, "tokens": n}

    return loss_fn
