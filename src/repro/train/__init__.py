"""Training substrate: losses, step builders (driver lives in repro.runtime)."""
from .loss import make_loss_fn, xent_chunked, xent_full
from .step import make_decode_step, make_prefill_step, make_train_step

__all__ = [
    "make_loss_fn",
    "xent_full",
    "xent_chunked",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
]
