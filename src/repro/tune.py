"""``python -m repro.tune`` — the umbrella CLI for the tuning fleet.

One front door for every offline tuning workflow::

    python -m repro.tune pretune --db tuned/cpu.json --smoke
    python -m repro.tune pretune --db tuned/s0.json --smoke --shard 0/2
    python -m repro.tune db merge --out tuned/all.json tuned/s0.json tuned/s1.json
    python -m repro.tune db list --db tuned/all.json
    python -m repro.tune db list --db tuned/all.json --grid --smoke
    python -m repro.tune db diff tuned/all.json tuned/unsharded.json

* ``pretune`` — the offline sweep (:mod:`repro.tuning.pretune`, every flag
  forwarded unchanged; ``python -m repro.tuning.pretune`` remains a shim
  over this subcommand).
* ``db merge`` — fold shard DBs into one, resolving per-key conflicts with
  the fleet's total-order keep-better rule
  (:func:`repro.tuning.fleet.merge_dbs`): associative, order-independent,
  and identical to what ``Autotuning.commit()`` would have kept.  Sources
  may also be run journals (``<db>.journal``) from workers that died
  mid-sweep — their committed records fold, interrupted cases are absent.
* ``db list`` — the records of a DB; ``--grid`` shows the registered
  pretune grid with per-case hit status instead (absorbing the historical
  ``pretune --list``), ``--shard i/n`` restricts either view to one fleet
  shard.
* ``db diff`` — compare two DBs' best points; exit 1 on any mismatch (the
  CI shard-equivalence gate).
* ``report`` — render the observability artifacts a ``pretune --obs-dir``
  run wrote (:mod:`repro.obs.report`): search timeline, per-phase time
  breakdown, candidate accounting, metrics, fleet shard health.  Exit 1
  when the event stream fails schema validation or the candidate
  accounting does not balance.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

__all__ = ["main"]

_USAGE = """usage: python -m repro.tune <command> ...

commands:
  pretune            offline tuning sweep (see: pretune --help)
  db merge           fold shard DBs into one (keep-better conflict resolution)
  db list            show a DB's records (--grid: the pretune grid + hit status)
  db diff            compare two DBs' best points; exit 1 on mismatch
  report             render search forensics from an --obs-dir directory
"""


def _open_db(path: str, *, must_exist: bool = True, autosave: bool = True):
    from repro.tuning import TuningDB

    if must_exist and not os.path.exists(path):
        raise FileNotFoundError(f"no tuning DB at {path}")
    return TuningDB(path, autosave=autosave)


def _open_source(path: str):
    """A merge source: a tuning DB file, or a run journal (``<db>.journal``)
    from a sweep that may have died mid-measurement — its committed records
    fold like any shard DB, interrupted cases are simply absent."""
    from repro.tuning import RunJournal

    if not os.path.exists(path):
        raise FileNotFoundError(f"no tuning DB at {path}")
    if RunJournal.is_journal(path):
        return RunJournal(path).to_db()
    return _open_db(path)


# ------------------------------------------------------------------ db merge
def _db_merge(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tune db merge",
        description="fold shard DBs into one, keep-better per key",
    )
    ap.add_argument("--out", required=True, help="destination DB (created/updated)")
    ap.add_argument(
        "sources", nargs="+", metavar="SRC",
        help="shard DB file(s) and/or run journals (<db>.journal) from "
             "workers that died mid-sweep — a journal's committed records "
             "fold like any shard DB",
    )
    args = ap.parse_args(argv)

    from repro.tuning import TuningDB
    from repro.tuning.fleet import merge_dbs

    try:
        sources = [_open_source(p) for p in args.sources]
    except FileNotFoundError as e:
        print(f"db merge: {e}", file=sys.stderr)
        return 2
    dest = TuningDB(args.out, autosave=False)
    stats = merge_dbs(dest, sources)
    dest.save()
    print(f"db merge: {stats} -> {args.out} ({len(dest)} records)")
    return 0


def _key_context(key) -> str:
    """Human-readable context column for a record's key.  Kernel keys render
    their argument shapes; launch-level keys have no array arguments
    (``shapes()`` is None) — their context lives in ``extra`` (shape name,
    device count, mode), so render that instead of the literal "None"."""
    shapes = key.shapes()
    if shapes is not None:
        return str(shapes)
    try:
        extra = json.loads(getattr(key, "extra", None) or "{}")
    except (TypeError, ValueError):
        extra = {}
    if extra:
        return "[" + " ".join(f"{k}={extra[k]}" for k in sorted(extra)) + "]"
    return "[no-args]"


# ------------------------------------------------------------------- db list
def _db_list(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tune db list", description="show a tuning DB's records"
    )
    ap.add_argument("--db", default="tuned/cpu.json", help="DB file to read")
    ap.add_argument(
        "--grid", action="store_true",
        help="list the registered pretune grid with per-case DB hit status "
             "(exact hit / warm neighbor / cold) instead of raw records",
    )
    ap.add_argument("--smoke", action="store_true",
                    help="with --grid: the smoke grid (CI lane)")
    ap.add_argument("--no-interpret", action="store_true",
                    help="with --grid: fingerprint compiled (non-interpret) contexts")
    ap.add_argument(
        "--shard", type=str, default=None, metavar="I/N",
        help="restrict to the contexts of one fleet shard",
    )
    args = ap.parse_args(argv)

    from repro.tuning import TuningDB

    db = TuningDB(args.db)
    shard = None
    if args.shard is not None:
        from repro.tuning.fleet import parse_shard

        shard = parse_shard(args.shard)

    if args.grid:
        from repro.tuning.pretune import _cases, _list_grid, _shard_filter

        cases = _cases(args.smoke, abstract=True)
        if shard is not None:
            cases = _shard_filter(cases, args.smoke, None, None, shard,
                                  interpret=not args.no_interpret)
        return _list_grid(cases, db, interpret=not args.no_interpret)

    records = db.records()
    if shard is not None:
        index, num = shard
        records = [r for r in records if r.key.shard(num) == index]
    where = f" shard {shard[0]}/{shard[1]}" if shard is not None else ""
    print(f"{args.db}: {len(records)} records{where}")
    for rec in sorted(records, key=lambda r: r.key.encode()):
        shapes = _key_context(rec.key)
        conf = (f" ±{rec.cost_std * 1e3:.2f}ms(n={rec.repeats_spent})"
                if rec.known_std() is not None else "")
        strat = f" strategy={rec.strategy}" if rec.strategy else ""
        print(
            f"  {rec.key.name:<18} {shapes:<34} best={rec.point} "
            f"cost={rec.cost * 1e3:.3f}ms{conf} source={rec.source}{strat}"
        )
    return 0


# ------------------------------------------------------------------- db diff
def _db_diff(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tune db diff",
        description="compare two DBs' best points; exit 1 on any mismatch",
    )
    ap.add_argument("a", metavar="A", help="first DB file")
    ap.add_argument("b", metavar="B", help="second DB file")
    ap.add_argument(
        "--costs", action="store_true",
        help="also require equal stored costs (default: points only — costs "
             "are measurement-noisy unless both runs used --cost analytic)",
    )
    args = ap.parse_args(argv)

    try:
        da, db_ = _open_db(args.a), _open_db(args.b)
    except FileNotFoundError as e:
        print(f"db diff: {e}", file=sys.stderr)
        return 2
    ka = {r.key.encode(): r for r in da.records()}
    kb = {r.key.encode(): r for r in db_.records()}
    bad = 0
    for k in sorted(set(ka) | set(kb)):
        ra, rb = ka.get(k), kb.get(k)
        if ra is None or rb is None:
            side = args.b if ra is None else args.a
            rec = rb if ra is None else ra
            print(f"  only in {side}: {rec.key.name} {_key_context(rec.key)}")
            bad += 1
        elif ra.point != rb.point:
            print(
                f"  point mismatch: {ra.key.name} {_key_context(ra.key)}: "
                f"{ra.point} (cost={ra.cost:.6g}) != {rb.point} (cost={rb.cost:.6g})"
            )
            bad += 1
        elif args.costs and ra.cost != rb.cost:
            print(
                f"  cost mismatch: {ra.key.name} {_key_context(ra.key)}: "
                f"{ra.cost:.6g} != {rb.cost:.6g}"
            )
            bad += 1
    if bad:
        print(f"db diff: {bad} mismatch(es) between {args.a} and {args.b}")
        return 1
    print(f"db diff: {args.a} and {args.b} agree on {len(ka)} records")
    return 0


# -------------------------------------------------------------------- report
def _report(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tune report",
        description="render search forensics from an --obs-dir directory",
    )
    ap.add_argument("obs_dir", metavar="OBS_DIR",
                    help="directory a run wrote via --obs-dir / REPRO_OBS")
    ap.add_argument("--db", default=None,
                    help="tuning DB whose run journal to include as shard health")
    ap.add_argument(
        "--journal", action="append", default=None, metavar="PATH",
        help="run journal(s) to include as fleet shard health; repeatable",
    )
    ap.add_argument(
        "--stale", type=float, default=300.0, metavar="SECONDS",
        help="age of the last journal event past which an interrupted shard "
             "counts as STALLED rather than live (default: 300)",
    )
    args = ap.parse_args(argv)

    if not os.path.isdir(args.obs_dir):
        print(f"report: no obs directory at {args.obs_dir}", file=sys.stderr)
        return 2

    from repro.obs.report import render_report

    text, code = render_report(
        args.obs_dir,
        db_path=args.db,
        journals=args.journal or (),
        stale_s=args.stale,
    )
    print(text, end="")
    return code


def _db(argv) -> int:
    if not argv or argv[0] in ("-h", "--help"):
        print("usage: python -m repro.tune db {merge,list,diff} ...")
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "merge":
        return _db_merge(rest)
    if cmd == "list":
        return _db_list(rest)
    if cmd == "diff":
        return _db_diff(rest)
    print(f"repro.tune db: unknown subcommand {cmd!r}", file=sys.stderr)
    print("usage: python -m repro.tune db {merge,list,diff} ...", file=sys.stderr)
    return 2


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print(_USAGE, file=sys.stderr)
        return 2
    if argv[0] in ("-h", "--help"):
        print(_USAGE)
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd == "pretune":
        # forwarded wholesale: the sweep owns its own (large) flag surface
        from repro.tuning.pretune import main as pretune_main

        return pretune_main(rest, prog="repro.tune pretune")
    if cmd == "db":
        return _db(rest)
    if cmd == "report":
        return _report(rest)
    print(f"repro.tune: unknown command {cmd!r}", file=sys.stderr)
    print(_USAGE, file=sys.stderr)
    return 2


if __name__ == "__main__":
    try:
        code = main()
    except BrokenPipeError:  # e.g. `... db list | head` closing the pipe
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        code = 0
    raise SystemExit(code)
