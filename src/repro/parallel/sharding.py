"""Parameter / batch / cache sharding rules (DP + FSDP/ZeRO-3 + TP + EP).

``param_wanted(path, shape)`` returns logical axes per dim (see api.py);
``tree_shardings`` converts a ShapeDtypeStruct tree into NamedShardings with
divisibility guards (heads that don't divide the model axis replicate —
e.g. qwen2's 28 heads on a 16-way axis shard via the fused H*hd dim of the
projection instead; GSPMD propagates internally).
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec

from .api import ShardingRules, logical_spec

__all__ = ["param_wanted", "batch_wanted", "state_wanted", "tree_shardings", "path_str"]


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _stacked(path: str) -> bool:
    return path.startswith("stages/") or path.startswith("encoder/stages/")


def _ndim(shape_or_ndim) -> int:
    return shape_or_ndim if isinstance(shape_or_ndim, int) else len(shape_or_ndim)


def param_wanted(path: str, shape) -> Tuple:
    """Logical placement per dim for a parameter leaf."""
    ndim = _ndim(shape)
    base_ndim = ndim - 1 if _stacked(path) else ndim

    def out(*axes):
        axes = tuple(axes) + (None,) * (base_ndim - len(axes))
        return ((None,) + axes) if _stacked(path) else axes

    # --- embeddings / head ---
    if re.search(r"embed/table$", path):
        return out("tp", "fsdp")
    if re.search(r"lm_head/w$", path):
        return out("fsdp", "tp")
    # --- attention ---
    if re.search(r"(attn|xattn)/w[qkv]/w$", path):
        return out("fsdp", "tp")
    if re.search(r"(attn|xattn)/w[qkv]/b$", path):
        return out("tp")
    if re.search(r"(attn|xattn)/wo/w$", path):
        return out("tp", "fsdp")
    # --- MoE experts (E, D, F) / (E, F, D); router (D, E) ---
    if re.search(r"ffn/(wi|wg)$", path) and base_ndim == 3:
        return out("ep", "fsdp", None)
    if re.search(r"ffn/wo$", path) and base_ndim == 3:
        return out("ep", None, "fsdp")
    if re.search(r"ffn/router$", path):
        return out("fsdp", None)
    # --- dense FFN (incl. arctic dense residual under ffn/dense/) ---
    if re.search(r"(ffn|dense|cm)/(wi|wg|wk)$", path) and base_ndim == 2:
        return out("fsdp", "tp")
    if re.search(r"(ffn|dense|cm)/(wo|wv)$", path) and base_ndim == 2:
        return out("tp", "fsdp")
    if re.search(r"ffn/bi$", path):
        return out("tp")
    # --- rwkv time-mix ---
    if re.search(r"tm/(wr|wk|wv|wg)$", path):
        return out("fsdp", "tp")
    if re.search(r"tm/wo$", path):
        return out("tp", "fsdp")
    if re.search(r"(tm/w1|tm/mix_w1|cm/wr)$", path):
        return out("fsdp", None) if "w1" in path else out("fsdp", "tp")
    if re.search(r"tm/w2$", path):
        return out(None, "fsdp")
    # --- rglru ---
    if re.search(r"rec/(wx_gelu|wx_rec|wa|wi)$", path):
        return out("fsdp", "tp")
    if re.search(r"rec/wo$", path):
        return out("tp", "fsdp")
    if re.search(r"rec/conv_w$", path):
        return out(None, "tp")
    if re.search(r"rec/(lam|ba|bi|conv_b)$", path):
        return out("tp")
    # --- everything else (norms, small LoRAs, u, biases): replicated ---
    return out()


def batch_wanted(name: str, shape) -> Tuple:
    ndim = _ndim(shape)
    if name in ("tokens", "labels"):
        return ("dp", "sp")[:ndim] if ndim == 2 else ("dp",) + (None,) * (ndim - 1)
    if name in ("frames", "ctx_embeds"):
        return ("dp", None, None)
    return ("dp",) + (None,) * (ndim - 1)


def state_wanted(path: str, shape, tp_size: int = 0) -> Tuple:
    """Decode caches / recurrent states (leading dim = group stack).

    KV caches prefer head sharding; when the KV head count does not divide
    the model axis (GQA kv=8 on a 16-way axis) the cache's *sequence* dim is
    sharded instead — the sharded-KV / flash-decode layout (the softmax over
    the sharded axis becomes two small all-reduces, handled by GSPMD).  This
    is what keeps e.g. llama3-405B decode_32k at ~9 GB/chip instead of 138."""
    ndim = _ndim(shape)

    def out(*axes):
        axes = tuple(axes) + (None,) * (ndim - 1 - len(axes))
        return (None,) + axes

    if re.search(r"/(k|v|xk|xv)$", path):  # (ng, B, Kh, W, hd)
        if (
            tp_size
            and not isinstance(shape, int)
            and shape[2] % tp_size != 0
            and shape[3] % tp_size == 0
        ):
            return out("dp", None, "tp", None)  # sharded-sequence KV
        return out("dp", "tp", None, None)
    if path.endswith("/pos"):  # (ng, W)
        return out()
    if path.endswith("/wkv"):  # (ng, B, H, hd, hd)
        return out("dp", "tp", None, None)
    if re.search(r"/(shift_tm|shift_cm|h)$", path):  # (ng, B, D)
        return out("dp", "tp")
    if path.endswith("/conv"):  # (ng, B, W-1, dr)
        return out("dp", None, "tp")
    return out()


def tree_shardings(mesh, rules: ShardingRules, tree, wanted_fn) -> object:
    """Map a ShapeDtypeStruct (or array) pytree to NamedShardings."""

    def leaf(path, x):
        p = path_str(path)
        wanted = wanted_fn(p, tuple(x.shape))
        spec = logical_spec(mesh, rules, x.shape, wanted)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, tree)


def replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, PartitionSpec()), tree)
