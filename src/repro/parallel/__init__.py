"""Distribution: logical-axis sharding (DP/FSDP/TP/EP/SP), pipeline, collectives."""
from .api import ShardingRules, constrain, logical_spec, sharding_context
from .devices import DeviceSlot, local_device_pool
