"""Device slots — the fleet's per-worker view of the host's accelerators.

A :class:`repro.tuning.fleet.ShardedPortfolio` race wants one measurement
worker per portfolio member; on a multi-device host each worker should own a
device so members measure concurrently instead of queueing on device 0.
:func:`local_device_pool` hands out that assignment: one
:class:`DeviceSlot` per worker, round-robin over the process's local jax
devices, each slot carrying its own partition of a shared
:class:`~repro.core.costs.ExecutableCache` (the same candidate compiled for
two devices is two distinct executables — partitioned keys keep them from
colliding while the LRU budget and stats stay shared).

On a CPU-only host (or when jax is unavailable) the slots have
``device=None`` and measurement falls back to plain host threads — the
fleet degrades to concurrency without device parallelism, never to an
error.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

__all__ = ["DeviceSlot", "local_device_pool"]


@dataclasses.dataclass
class DeviceSlot:
    """One fleet worker's execution context: its index, the jax device it
    pins measurements to (None → default placement), and its namespaced
    executable cache."""

    index: int
    device: Optional[Any]
    cache: Optional[Any] = None

    def __str__(self) -> str:
        dev = "host" if self.device is None else str(self.device)
        return f"slot{self.index}[{dev}]"


def local_device_pool(num_slots: int, *, cache=None) -> List[DeviceSlot]:
    """``num_slots`` device slots over the process's local jax devices,
    round-robin (8 slots on 4 chips → each chip serves two workers).  When
    ``cache`` (an :class:`~repro.core.costs.ExecutableCache`) is given,
    every slot gets a per-*device* partition of it, so workers sharing a
    chip also share its compiled executables."""
    if num_slots < 1:
        raise ValueError(f"num_slots must be >= 1, got {num_slots}")
    try:
        import jax

        devices = list(jax.local_devices())
    except Exception:
        devices = []
    slots = []
    for i in range(num_slots):
        device = devices[i % len(devices)] if devices else None
        part = None
        if cache is not None:
            tag = f"dev{i % len(devices)}" if devices else "host"
            part = cache.partition(tag)
        slots.append(DeviceSlot(index=i, device=device, cache=part))
    return slots
