"""Logical-axis sharding context: model code asks for logical placements,
the active context maps them to mesh axes (with divisibility guards).

Model code calls ``constrain(x, ("dp", "sp", None))`` at block boundaries;
without an active context this is a no-op (single-device tests), inside
``sharding_context(mesh, rules)`` it becomes a with_sharding_constraint.

Logical axes:
  dp  — data parallel (batch dims):        ("data",) or ("pod", "data")
  tp  — tensor parallel (heads/ff/vocab):  "model"
  sp  — sequence parallel (activations):   None (off) or "model"
  ep  — expert parallel:                   "model"
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

__all__ = ["ShardingRules", "sharding_context", "constrain", "current_rules", "logical_spec"]

Axis = Union[None, str, Tuple[str, ...]]

_TLS = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    dp: Axis = ("data",)
    tp: Axis = "model"
    sp: Axis = None  # sequence-parallel activations (hillclimb knob)
    ep: Axis = "model"
    fsdp: Axis = ("data",)  # weight sharding axes (ZeRO-3); None disables

    def resolve(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        return getattr(self, logical)


def _axis_size(mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return int(np.prod([mesh.shape[a] for a in axis]))


def logical_spec(mesh, rules: ShardingRules, shape, wanted) -> PartitionSpec:
    """Map logical axes to a PartitionSpec, dropping any axis whose size does
    not divide the corresponding dim (JAX requires exact divisibility)."""
    entries = []
    for dim, logical in zip(shape, wanted):
        axis = rules.resolve(logical)
        if axis is not None and dim % _axis_size(mesh, axis) == 0 and dim > 0:
            entries.append(axis)
        else:
            entries.append(None)
    return PartitionSpec(*entries)


@contextlib.contextmanager
def sharding_context(mesh, rules: ShardingRules):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, rules)
    try:
        yield
    finally:
        _TLS.ctx = prev


def current_rules():
    return getattr(_TLS, "ctx", None)


def constrain(x, wanted):
    """Apply a logical-axes sharding constraint if a context is active."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_spec(mesh, rules, x.shape, wanted)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_first(x, *options):
    """Apply the first option whose every requested axis survives the
    divisibility guard (fallback: the first option, with drops)."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    for wanted in options:
        spec = logical_spec(mesh, rules, x.shape, wanted)
        requested = sum(1 for w in wanted if w is not None and rules.resolve(w) is not None)
        granted = sum(1 for e in spec if e is not None)
        if granted == requested:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    spec = logical_spec(mesh, rules, x.shape, options[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
