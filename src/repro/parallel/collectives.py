"""Distributed-optimization collectives: compressed gradient reduction.

Cross-pod links are the scarcest bandwidth at 1000-node scale; these
utilities trade precision for wire bytes on the DP all-reduce:

  * ``int8_psum``      — per-tensor-scaled int8 quantized psum (≈4× fewer
                          bytes than fp32 on the wire), with stochastic-free
                          deterministic rounding;
  * ``topk_psum``      — magnitude top-k sparsification with **error
                          feedback** (the residual is carried to the next
                          step, so the compression bias vanishes over time —
                          Seide et al. / Deep Gradient Compression);
  * ``make_compressed_dp_step`` — explicit-DP train step (shard_map over the
                          data axis) wiring either compressor into the
                          gradient reduction, with the error-feedback state
                          threaded through the step signature.

The implicit-SPMD train path keeps XLA's native all-reduce; this module is
the explicit path for bandwidth-starved cross-pod reductions (benchmarked in
benchmarks/grad_compression.py, tested in tests/test_collectives.py).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._compat import shard_map

__all__ = [
    "int8_psum",
    "topk_psum",
    "chunked_psum",
    "make_compressed_dp_step",
    "wire_bytes",
]


def int8_psum(g: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Quantize to int8 with a shared (psum-max) scale, reduce, dequantize."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int32)
    s = jax.lax.psum(q, axis)
    return s.astype(jnp.float32) * scale


def topk_psum(g: jnp.ndarray, axis: str, k_ratio: float, err: jnp.ndarray):
    """Error-feedback top-k: reduce only the largest |g+err| entries.

    Returns (reduced_dense, new_err).  Wire bytes ≈ 2 * k * 8 (values+indices)
    vs n * 4 dense — here emulated with a masked dense psum (the wire-cost
    model is what the benchmark reports; a production impl would use
    sparse collectives or gather-based exchange)."""
    ge = g + err
    flat = ge.reshape(-1)
    k = max(1, int(flat.size * k_ratio))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(ge) >= thresh).astype(g.dtype)
    sparse = ge * mask
    new_err = ge - sparse  # residual carried to the next step
    return jax.lax.psum(sparse, axis), new_err


def chunked_psum(g: jnp.ndarray, axis: str, chunk_bytes: int) -> jnp.ndarray:
    """All-reduce ``g`` in fixed-size chunks of ≤ ``chunk_bytes`` each.

    Collective chunking is a launch-level knob (``launch.spaces``): smaller
    chunks let an async scheduler overlap the reduction with compute and
    bound the per-op ICI buffer, at the price of per-chunk dispatch latency;
    one huge all-reduce is the opposite trade.  The reduction is exact — the
    result equals ``jax.lax.psum(g, axis)`` bit-for-bit in fp32 — only the
    op granularity changes (one psum per chunk via ``lax.map``)."""
    if chunk_bytes <= 0:
        raise ValueError(f"chunk_bytes must be positive, got {chunk_bytes}")
    per = max(1, int(chunk_bytes) // g.dtype.itemsize)
    flat = g.reshape(-1)
    if flat.size <= per:
        return jax.lax.psum(g, axis)
    n_chunks = -(-flat.size // per)
    pad = n_chunks * per - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n_chunks, per)
    reduced = jax.lax.map(lambda c: jax.lax.psum(c, axis), chunks)
    return reduced.reshape(-1)[: g.size].reshape(g.shape)


def wire_bytes(tree, method: str, k_ratio: float = 0.01) -> int:
    """Wire-cost model per DP all-reduce (ring: 2(n-1)/n ≈ 2x size)."""
    n = sum(x.size for x in jax.tree.leaves(tree))
    if method == "fp32":
        per = 4 * n
    elif method == "bf16":
        per = 2 * n
    elif method == "int8":
        per = 1 * n + 4
    elif method == "topk":
        per = int(n * k_ratio) * (4 + 4)  # value + index
    else:
        raise ValueError(method)
    return 2 * per


def make_compressed_dp_step(
    loss_fn: Callable,
    optimizer,
    mesh,
    *,
    axis: str = "data",
    method: str = "int8",
    k_ratio: float = 0.01,
    chunk_bytes: int = 0,
):
    """Explicit-DP train step: per-device grads on the local microbatch, then
    a compressed cross-device reduction.  Params replicated over ``axis``.

    step(params, opt_state, err_state, batch) ->
        (params, opt_state, err_state, metrics)
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    nd = mesh.shape[axis]

    def local_step(params, opt_state, err, batch):
        (loss, aux), grads = grad_fn(params, batch)
        if method == "int8":
            grads = jax.tree.map(lambda g: int8_psum(g / nd, axis), grads)
            new_err = err
        elif method == "topk":
            out = jax.tree.map(
                lambda g, e: topk_psum(g / nd, axis, k_ratio, e), grads, err
            )
            grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
            new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        elif method == "chunked":
            grads = jax.tree.map(
                lambda g: chunked_psum(g / nd, axis, chunk_bytes or g.nbytes), grads
            )
            new_err = err
        else:  # exact
            grads = jax.tree.map(lambda g: jax.lax.psum(g / nd, axis), grads)
            new_err = err
        loss = jax.lax.pmean(loss, axis)
        params, opt_state, om = optimizer.update(grads, opt_state, params)
        return params, opt_state, new_err, dict(aux, loss=loss, **om)

    def rep(tree):
        return jax.tree.map(lambda _: P(), tree)

    def step(params, opt_state, err, batch):
        batch_specs = jax.tree.map(lambda _: P(axis), batch)
        fn = shard_map(
            local_step,
            mesh=mesh,
            in_specs=(rep(params), rep(opt_state), rep(err), batch_specs),
            out_specs=(rep(params), rep(opt_state), rep(err), P()),
            check_vma=False,
        )
        return fn(params, opt_state, err, batch)

    def init_err(params):
        if method == "topk":
            return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)

    return step, init_err
