"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The stage axis (``pod`` on the multi-pod mesh) holds a contiguous slice of
layer groups per device row.  Microbatches flow through the classic GPipe
schedule: at tick t, stage s processes microbatch (t - s); activations hop
stage→stage+1 via ``jax.lax.ppermute``.  Bubble fraction = (S-1)/(M+S-1).

Autodiff gives the backward schedule for free (ppermute transposes to the
reverse permutation), so this composes with jax.grad for training.  Used by
the dry-run ``--pp`` variant and tests/test_pipeline.py; the default
multi-pod config uses hierarchical DP over the pod axis instead (DESIGN §6).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["gpipe_apply"]


def gpipe_apply(
    mesh,
    axis: str,
    stage_fn: Callable,  # (stage_params, x_mb) -> y_mb
    stage_params,  # pytree; leaves with leading dim == n_stages (sharded over axis)
    x,  # (M, mb, ...) microbatched inputs (replicated over axis)
):
    """Run x through S pipeline stages; returns (M, mb, ...) outputs."""
    S = mesh.shape[axis]
    M = x.shape[0]

    def per_stage(params_local, x_all):
        # params_local: leaves (1, ...) — this stage's slice
        params_local = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        T = M + S - 1
        mb_shape = x_all.shape[1:]
        carry = jnp.zeros(mb_shape, x_all.dtype)
        ys = jnp.zeros_like(x_all)

        def tick(t, state):
            carry, ys = state
            # stage 0 ingests microbatch t (if still available)
            mb_in = x_all[jnp.minimum(t, M - 1)]
            inp = jnp.where(sid == 0, mb_in, carry)
            out = stage_fn(params_local, inp)
            # last stage emits microbatch (t - (S-1))
            oidx = jnp.clip(t - (S - 1), 0, M - 1)
            emit = (sid == S - 1) & (t >= S - 1)
            ys = jax.lax.dynamic_update_index_in_dim(
                ys, jnp.where(emit, out, ys[oidx]), oidx, axis=0
            )
            # shift activations one stage forward
            carry = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % S) for i in range(S)]
            )
            return carry, ys

        carry, ys = jax.lax.fori_loop(0, T, tick, (carry, ys))
        # only the last stage's ys are the real outputs; broadcast them
        ys = jnp.where(sid == S - 1, ys, jnp.zeros_like(ys))
        return jax.lax.psum(ys, axis)

    from ._compat import shard_map

    specs_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(specs_params, P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(stage_params, x)
