"""Version compatibility for parallelism symbols.

``shard_map`` was promoted out of ``jax.experimental`` and its
``check_rep`` kwarg renamed to ``check_vma`` in newer jax releases;
resolve whichever this interpreter provides and accept the modern
kwarg name at every call site.
"""
from __future__ import annotations

import inspect

try:
    from jax import shard_map as _shard_map
except ImportError:  # older jax: pre-promotion name
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:

    def shard_map(*args, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        return _shard_map(*args, **kwargs)


__all__ = ["shard_map"]
