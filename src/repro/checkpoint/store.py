"""Checkpoint store.

Design (DESIGN.md §7):
  * **atomic**: write to ``<dir>/tmp.<step>/`` then ``os.rename`` — a crash
    mid-save never corrupts the latest-complete pointer;
  * **integrity**: per-array crc32 in a JSON manifest, verified on load;
  * **async**: ``save_async`` hands the (host-transferred) arrays to a
    background thread so the train loop returns to stepping immediately;
  * **elastic**: arrays are stored unsharded (gathered); restore reshards to
    whatever mesh the new job runs on — device-count changes are transparent
    (tested in tests/test_fault_tolerance.py);
  * **keep-k**: old steps garbage-collected after a successful save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Optional

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint"]

_MANIFEST = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(directory: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the final step directory."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, _ = _flatten(tree)
    manifest = {"step": step, "arrays": {}, "extra": extra or {}}
    for key, arr in arrays.items():
        fname = f"a{len(manifest['arrays']):06d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["arrays"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
        }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
            os.path.join(directory, name, _MANIFEST)
        ):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, like_tree, step: Optional[int] = None,
                    shardings=None):
    """Load into the structure of ``like_tree``; verifies checksums; reshards
    to ``shardings`` (a matching pytree of NamedShardings) if given — this is
    the elastic-restore path (old mesh -> new mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    arrays = {}
    for key, meta in manifest["arrays"].items():
        arr = np.load(os.path.join(d, meta["file"]))
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
        if crc != meta["crc32"]:
            raise IOError(f"checksum mismatch for {key} in {d}")
        arrays[key] = arr

    leaves, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
    out = []
    for i, (path, like) in enumerate(leaves):
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key].astype(like.dtype)
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"], manifest["extra"]


class CheckpointManager:
    """Async keep-k checkpointing around a directory."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree, extra: Optional[dict] = None):
        """Transfer to host now (cheap relative to a step), write in the
        background — the caller keeps training while the npz files stream."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        save_checkpoint(self.directory, step, tree, extra)
        self._gc()

    def restore(self, like_tree, shardings=None):
        self.wait()
        return load_checkpoint(self.directory, like_tree, shardings=shardings)

    def latest_step(self):
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)
