"""Fault-tolerant checkpointing: atomic sharded npz + manifest + checksums,
async save, keep-last-k, mesh-elastic restore."""
from .store import CheckpointManager, load_checkpoint, save_checkpoint

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint"]
