"""Model: embeddings → staged block stacks (scan-over-groups) → LM head.

One class serves all 10 assigned architectures: dense / MoE decoders,
attention-free RWKV-6, the Griffin 1:2 hybrid, the seamless encoder–decoder
(audio frontend stubbed as precomputed frame embeddings) and the llama-3.2
vision backbone (patch embeddings stubbed, cross-attention layers real).

Layer stacking: the repeating group is the body of a ``jax.lax.scan`` with
per-group stacked parameters, so a 126-layer model lowers to one small HLO
loop body; ``ExecConfig.scan_unroll`` / ``remat`` control the unroll factor
and activation-checkpoint policy (both PATSMA-tunable).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.api import constrain

from .blocks import apply_block, init_block, init_block_state
from .config import ExecConfig, ModelConfig
from .layers import embed_init, norm_apply, norm_init, _nrm

__all__ = ["Model"]


def _stack_init(fn, rng, n: int):
    """Initialize n copies of params with independent keys, stacked on axis 0."""
    return jax.vmap(fn)(jax.random.split(rng, n))


class Model:
    def __init__(self, cfg: ModelConfig, exec_cfg: ExecConfig = ExecConfig()):
        self.cfg = cfg
        self.exec_cfg = exec_cfg
        # stage definitions: [(kinds, n_groups)]
        self.stage_defs = []
        if cfg.n_groups > 0:
            self.stage_defs.append((cfg.group, cfg.n_groups))
        if cfg.tail:
            self.stage_defs.append((cfg.tail, 1))

    # ------------------------------------------------------------------ init
    def init(self, rng) -> dict:
        cfg = self.cfg
        keys = jax.random.split(rng, 8)
        params = {
            "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model),
            "final_norm": norm_init(cfg.norm, cfg.d_model),
            "stages": [
                _stack_init(
                    lambda k, kinds=kinds: {
                        f"pos{i}": init_block(kind, jax.random.fold_in(k, i), cfg)
                        for i, kind in enumerate(kinds)
                    },
                    jax.random.fold_in(keys[1], si),
                    ng,
                )
                for si, (kinds, ng) in enumerate(self.stage_defs)
            ],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": _nrm(keys[2], (cfg.d_model, cfg.padded_vocab), cfg.d_model**-0.5)
            }
        if cfg.is_encdec:
            enc_cfg = cfg  # same dims; bidirectional attn blocks
            params["encoder"] = {
                "stages": [
                    _stack_init(
                        lambda k: {"pos0": init_block("attn", k, enc_cfg)},
                        keys[3],
                        cfg.enc_layers,
                    )
                ],
                "norm": norm_init(cfg.norm, cfg.d_model),
            }
        p_dt = jnp.dtype(cfg.param_dtype)
        return jax.tree.map(lambda a: a.astype(p_dt), params)

    # ------------------------------------------------------------ stack exec
    def _run_stack(
        self,
        stage_defs,
        stages_params,
        x,
        states,
        *,
        q_pos,
        ctx,
        mode,
        causal,
    ):
        ec = self.exec_cfg
        aux = jnp.zeros((), jnp.float32)
        new_states = []
        for si, (kinds, ng) in enumerate(stage_defs):
            body = self.make_stage_body(kinds, q_pos=q_pos, ctx=ctx, mode=mode, causal=causal)
            (x, aux), st_out = jax.lax.scan(
                body,
                (x, aux),
                (stages_params[si], states[si]),
                unroll=max(1, min(ec.scan_unroll, ng)),
            )
            new_states.append(st_out)
        return x, new_states, aux

    def make_stage_body(self, kinds, *, q_pos, ctx, mode, causal):
        """The per-group scan body: carry (x, aux); xs (group_params, group_state).
        Exposed so the dry-run cost probes can lower one body in isolation
        (cost_analysis counts while-loop bodies once; see launch/costing.py)."""
        ec = self.exec_cfg

        def body(carry, xs):
            xc, auxc = carry
            xc = constrain(xc, ("dp", "sp", None))
            gp, gst = xs
            out_st = {}
            for i, kind in enumerate(kinds):
                xc, st_i, a = apply_block(
                    kind,
                    self.cfg,
                    gp[f"pos{i}"],
                    xc,
                    gst.get(f"pos{i}"),
                    q_pos=q_pos,
                    ctx=ctx,
                    mode=mode,
                    causal=causal,
                    exec_cfg=ec,
                )
                out_st[f"pos{i}"] = st_i
                auxc = auxc + a
            return (xc, auxc), out_st

        if ec.remat == "full":
            body = jax.checkpoint(body)
        elif ec.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        return body

    # ------------------------------------------------------------ embeddings
    def embed(self, params, tokens):
        x = params["embed"]["table"].astype(jnp.dtype(self.cfg.compute_dtype))[tokens]
        return x

    def head_weights(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"]["table"].T
        return params["lm_head"]["w"]

    def logits(self, params, x):
        w = self.head_weights(params).astype(x.dtype)
        return x @ w

    # --------------------------------------------------------------- encoder
    def encode(self, params, frames):
        """frames: (B, S_enc, D) precomputed embeddings (modality stub)."""
        B, S = frames.shape[:2]
        enc_defs = [(("attn",), self.cfg.enc_layers)]
        x = frames.astype(jnp.dtype(self.cfg.compute_dtype))
        states = self._init_states_for(enc_defs, B, S, mode="train")
        x, _, _ = self._run_stack(
            enc_defs,
            params["encoder"]["stages"],
            x,
            states,
            q_pos=jnp.arange(S),
            ctx=None,
            mode="train",
            causal=False,
        )
        return norm_apply(self.cfg.norm, params["encoder"]["norm"], x)

    def _context(self, params, batch: dict) -> Optional[jnp.ndarray]:
        if self.cfg.is_encdec:
            return self.encode(params, batch["frames"])
        if self.cfg.family == "vlm":
            return batch["ctx_embeds"].astype(jnp.dtype(self.cfg.compute_dtype))
        return None

    # ----------------------------------------------------------------- modes
    def forward(self, params, batch: dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Teacher-forced full-sequence pass.  batch["tokens"]: (B,S) inputs.
        Returns (hidden (B,S,D), aux_loss); logits via self.logits (or the
        chunked loss path in training, which never materializes them)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        ctx = self._context(params, batch)
        x = self.embed(params, tokens)
        states = self.init_states(B, S, mode="train")
        x, _, aux = self._run_stack(
            self.stage_defs,
            params["stages"],
            x,
            states,
            q_pos=jnp.arange(S),
            ctx=ctx,
            mode="train",
            causal=True,
        )
        x = norm_apply(self.cfg.norm, params["final_norm"], x)
        return x, aux

    def init_states(self, batch: int, max_len: int, mode: str):
        """Stacked per-stage states (None-free pytree; {} for stateless)."""
        return self._init_states_for(self.stage_defs, batch, max_len, mode)

    def _init_states_for(self, stage_defs, batch: int, max_len: int, mode: str):
        out = []
        for kinds, ng in stage_defs:
            one = {
                f"pos{i}": init_block_state(
                    kind, self.cfg, batch, max_len, mode, window=self.cfg.window
                )
                for i, kind in enumerate(kinds)
            }
            out.append(
                jax.tree.map(lambda a: jnp.broadcast_to(a[None], (ng,) + a.shape), one)
            )
        return out

    def prefill(self, params, batch: dict) -> Tuple[jnp.ndarray, list]:
        """Run the prompt, build caches.  Returns (last-token hidden, caches)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = batch.get("max_len", S)
        ctx = self._context(params, batch)
        x = self.embed(params, tokens)
        states = self.init_states(B, max_len, mode="prefill")
        x, states, _ = self._run_stack(
            self.stage_defs,
            params["stages"],
            x,
            states,
            q_pos=jnp.arange(S),
            ctx=ctx,
            mode="prefill",
            causal=True,
        )
        x = norm_apply(self.cfg.norm, params["final_norm"], x[:, -1:])
        return x[:, 0], states

    def decode_step(self, params, token, states, pos) -> Tuple[jnp.ndarray, list]:
        """One token for every sequence in the batch.  token: (B,1) int32;
        pos: () int32 current absolute position.  Returns (logits (B,V), states)."""
        x = self.embed(params, token)
        q_pos = pos[None] if jnp.ndim(pos) == 0 else pos
        x, states, _ = self._run_stack(
            self.stage_defs,
            params["stages"],
            x,
            states,
            q_pos=q_pos,
            ctx=None,
            mode="decode",
            causal=True,
        )
        x = norm_apply(self.cfg.norm, params["final_norm"], x)
        return self.logits(params, x)[:, 0], states
