"""Block kinds: init / state-init / apply, dispatched by kind string.

A block is the unit of the layer pattern (config.group/tail).  All apply
functions share the signature::

    apply_block(kind, cfg, p, x, st, *, q_pos, ctx, mode, causal, exec_cfg)
        -> (x, new_state, aux_loss)

``st`` is the block's cache/state ({} for stateless train-mode attention);
``ctx`` is the cross-attention context embeddings (B, N, D) when present.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attn_apply, attn_init, cross_kv, init_attn_cache
from .config import ExecConfig, ModelConfig
from .layers import ffn_apply, ffn_init, norm_apply, norm_init
from .moe import moe_apply, moe_init
from .rglru import init_rglru_state, rglru_apply, rglru_init
from .rwkv6 import init_rwkv_state, rwkv_apply, rwkv_init

__all__ = ["init_block", "init_block_state", "apply_block", "BLOCK_KINDS"]

BLOCK_KINDS = ("attn", "cross", "rwkv", "rglru")


def _ffn_params(rng, cfg: ModelConfig):
    if cfg.ffn == "moe":
        return moe_init(rng, cfg)
    return ffn_init(rng, cfg.ffn, cfg.d_model, cfg.d_ff)


def _apply_ffn(cfg: ModelConfig, p, x):
    if cfg.ffn == "moe":
        return moe_apply(cfg, p, x)
    return ffn_apply(cfg.ffn, p, x), jnp.zeros((), jnp.float32)


def init_block(kind: str, rng, cfg: ModelConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    d = cfg.d_model
    if kind == "attn":
        return {
            "norm1": norm_init(cfg.norm, d),
            "attn": attn_init(k1, cfg),
            "norm2": norm_init(cfg.norm, d),
            "ffn": _ffn_params(k2, cfg),
        }
    if kind == "cross":
        return {
            "norm1": norm_init(cfg.norm, d),
            "attn": attn_init(k1, cfg),
            "normx": norm_init(cfg.norm, d),
            "xattn": attn_init(k3, cfg),
            "norm2": norm_init(cfg.norm, d),
            "ffn": _ffn_params(k2, cfg),
        }
    if kind == "rwkv":
        return rwkv_init(k1, cfg)
    if kind == "rglru":
        return {
            "norm1": norm_init(cfg.norm, d),
            "rec": rglru_init(k1, cfg),
            "norm2": norm_init(cfg.norm, d),
            "ffn": ffn_init(k2, cfg.ffn if cfg.ffn != "moe" else "geglu", d, cfg.d_ff),
        }
    raise ValueError(f"unknown block kind {kind}")


def init_block_state(
    kind: str, cfg: ModelConfig, batch: int, max_len: int, mode: str, *, window: int
) -> dict:
    """State/cache for one block instance.  Train mode: only recurrent kinds
    carry state (zero-init); attention needs none."""
    if kind == "attn":
        if mode == "train":
            return {}
        return {"kv": init_attn_cache(cfg, batch, max_len, window=window)}
    if kind == "cross":
        if mode == "train":
            return {}
        n_ctx = cfg.ctx_tokens
        dt = jnp.dtype(cfg.compute_dtype)
        return {
            "kv": init_attn_cache(cfg, batch, max_len, window=window),
            "xk": jnp.zeros((batch, cfg.n_kv_heads, n_ctx, cfg.d_head), dt),
            "xv": jnp.zeros((batch, cfg.n_kv_heads, n_ctx, cfg.d_head), dt),
        }
    if kind == "rwkv":
        return init_rwkv_state(cfg, batch)
    if kind == "rglru":
        st = init_rglru_state(cfg, batch)
        return st
    raise ValueError(f"unknown block kind {kind}")


def apply_block(
    kind: str,
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    st: Optional[dict],
    *,
    q_pos: jnp.ndarray,
    ctx: Optional[jnp.ndarray],
    mode: str,
    causal: bool,
    exec_cfg: ExecConfig,
) -> Tuple[jnp.ndarray, dict, jnp.ndarray]:
    zero = jnp.zeros((), jnp.float32)
    st = st or {}
    if kind in ("attn", "cross"):
        h, new_kv = attn_apply(
            cfg,
            p["attn"],
            norm_apply(cfg.norm, p["norm1"], x),
            q_pos=q_pos,
            cache=st.get("kv"),
            causal=causal,
            window=cfg.window,
            exec_cfg=exec_cfg,
        )
        x = x + h
        new_st = {"kv": new_kv} if new_kv is not None else {}
        if kind == "cross":
            if mode == "decode":
                xkv = (st["xk"], st["xv"])
            else:
                xkv = cross_kv(cfg, p["xattn"], ctx)
            h, _ = attn_apply(
                cfg,
                p["xattn"],
                norm_apply(cfg.norm, p["normx"], x),
                q_pos=q_pos,
                kv=xkv,
                causal=False,
                rope=False,
                exec_cfg=exec_cfg,
            )
            x = x + h
            if mode != "train":
                new_st["xk"], new_st["xv"] = xkv
        h, aux = _apply_ffn(cfg, p["ffn"], norm_apply(cfg.norm, p["norm2"], x))
        return x + h, new_st, aux

    if kind == "rwkv":
        y, new_st = rwkv_apply(cfg, p, x, st, exec_cfg=exec_cfg)
        return y, new_st, zero

    if kind == "rglru":
        h, new_st = rglru_apply(
            cfg, p["rec"], norm_apply(cfg.norm, p["norm1"], x), st, exec_cfg=exec_cfg
        )
        x = x + h
        h = ffn_apply(
            cfg.ffn if cfg.ffn != "moe" else "geglu",
            p["ffn"],
            norm_apply(cfg.norm, p["norm2"], x),
        )
        return x + h, new_st, zero

    raise ValueError(f"unknown block kind {kind}")
