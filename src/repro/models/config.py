"""Model configuration — one dataclass covers all 10 assigned architectures.

A model is a stack of *blocks* arranged as ``group * n_groups + tail``; the
repeating group is the unit of ``jax.lax.scan`` so 126-layer models lower to
small HLO.  Block kinds:

  * ``attn``  — self-attention (GQA/MQA, optional window/bias) + FFN
  * ``cross`` — self-attention + cross-attention (to ``ctx``) + FFN
  * ``rwkv``  — RWKV-6 time-mix + channel-mix (attention-free)
  * ``rglru`` — Griffin recurrent block (conv1d + RG-LRU) + FFN

FFN kinds: ``swiglu`` / ``geglu`` / ``gelu`` / ``moe`` (capacity-factor
dispatch, optional dense residual — Arctic).  Encoder–decoder models add an
encoder stack of bidirectional ``attn`` blocks (seamless).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ExecConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    group: Tuple[str, ...] = ("attn",)
    n_groups: int = 0  # 0 -> n_layers // len(group)
    tail: Tuple[str, ...] = ()
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    ffn: str = "swiglu"  # swiglu | geglu | gelu | moe
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    window: int = 0  # local attention window (0 = global)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25  # PATSMA-tunable
    # --- recurrence (rglru) ---
    d_rnn: int = 0  # 0 -> d_model
    conv_width: int = 4
    # --- encoder-decoder / cross-attention context ---
    enc_layers: int = 0  # >0 -> encoder-decoder (seamless)
    ctx_tokens: int = 0  # default context length (vlm image tokens / enc frames)
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256

    # ------------------------------------------------------------- derived
    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_groups == 0 and self.group:
            ng, rem = divmod(self.n_layers - len(self.tail), len(self.group))
            if rem:
                raise ValueError(
                    f"{self.name}: n_layers={self.n_layers} does not tile as "
                    f"{self.group} * n + {self.tail}"
                )
            object.__setattr__(self, "n_groups", ng)
        expect = len(self.group) * self.n_groups + len(self.tail)
        if expect != self.n_layers:
            raise ValueError(f"{self.name}: pattern covers {expect} != {self.n_layers} layers")

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.group * self.n_groups + self.tail

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def uses_cross_attn(self) -> bool:
        return "cross" in self.pattern

    @property
    def attention_free(self) -> bool:
        return all(k in ("rwkv",) for k in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no *global* attention layer exists (long-context capable)."""
        has_global_attn = any(
            k in ("attn", "cross") for k in self.pattern
        ) and self.window == 0
        return not has_global_attn

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, hd = self.d_model, self.d_head
        qkv_out = (self.n_heads + 2 * self.n_kv_heads) * hd
        attn = d * qkv_out + self.n_heads * hd * d
        if self.qkv_bias:
            attn += qkv_out
        ffn = {
            "swiglu": 3 * d * self.d_ff,
            "geglu": 3 * d * self.d_ff,
            "gelu": 2 * d * self.d_ff,
        }.get(self.ffn)
        if self.ffn == "moe":
            ffn = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
            if self.moe_dense_residual:
                ffn += 3 * d * self.d_ff
        dr = self.rnn_width
        rglru = 2 * d * dr + dr * d + self.conv_width * dr + 3 * dr + dr * dr // 8
        glu_ffn = 3 * d * self.d_ff
        rwkv_tm = 4 * d * d + d * (64 * 2) + d * (5 * 32) * 2 + 6 * d + d * d
        rwkv_cm = 2 * d * self.d_ff + d * d
        per_kind = {
            "attn": attn + (ffn or 0),
            "cross": attn + attn + (ffn or 0),
            "rwkv": rwkv_tm + rwkv_cm,
            "rglru": rglru + glu_ffn,
        }
        total = sum(per_kind[k] for k in self.pattern)
        if self.enc_layers:
            total += self.enc_layers * (attn + (ffn or 0))
        total += self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts) for 6·N_active·D."""
        if self.ffn != "moe":
            return self.param_count()
        full = self.param_count()
        moe_total = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff_expert
        moe_active = self.n_layers * self.top_k * 3 * self.d_model * self.d_ff_expert
        return int(full - moe_total + moe_active)


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution-time knobs (most are PATSMA-tunable; model-agnostic)."""

    attn_impl: str = "xla"  # xla | pallas
    scan_layers: bool = True
    scan_unroll: int = 1
    remat: str = "none"  # none | full | dots  (activation checkpointing)
    logits_chunk: int = 0  # 0 = unchunked loss; else vocab-chunked CE
    rec_chunk: int = 128  # linear-recurrence chunk length (rwkv/rglru)
    rec_unroll: bool = False  # unroll the chunk loop (exact dry-run cost_analysis)
    block_q: int = 128  # pallas flash attention tiles
    block_kv: int = 128
    interpret: bool = False  # pallas interpret mode (CPU tests)
