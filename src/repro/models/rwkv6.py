"""RWKV-6 "Finch" block: data-dependent-decay linear recurrence (arXiv:2404.05892).

Per head with state S ∈ R^{hd×hd}, per-channel data-dependent decay w_t∈(0,1):

    y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Sequence processing uses an **exact, numerically stable chunked** form (scan
over chunks of length L = ExecConfig.rec_chunk, matmuls within):
all exponentials are of non-positive arguments (cumulative-decay differences
with s ≤ t and chunk-end references), so nothing overflows — no decay clamp
is needed.  The Pallas kernel (kernels/rwkv_scan.py) implements the same
algorithm with VMEM-resident state; ``ref.py``-style exactness is provided by
:func:`wkv_scan_ref` (naive per-token scan), which is also the decode path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ExecConfig, ModelConfig
from .layers import _nrm, norm_apply

__all__ = ["rwkv_init", "rwkv_apply", "init_rwkv_state", "wkv_scan_ref", "wkv_chunked"]

_LORA_W = 64  # decay LoRA rank (rwkv6 default for 7B)
_LORA_MIX = 32  # ddlerp LoRA rank


def rwkv_init(rng, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.d_head
    ks = jax.random.split(rng, 12)
    s = 1.0 / np.sqrt(d)
    return {
        "ln1": {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
        "ln2": {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
        "tm": {
            "mu_x": jnp.zeros((d,), jnp.float32),
            "mu_5": jnp.zeros((5, d), jnp.float32),  # w,k,v,r,g ddlerp biases
            "mix_w1": _nrm(ks[0], (d, 5 * _LORA_MIX), s),
            "mix_w2": _nrm(ks[1], (5, _LORA_MIX, d), 0.02),
            "w0": jnp.full((d,), -1.0, jnp.float32),  # decay bias (log-log space)
            "w1": _nrm(ks[2], (d, _LORA_W), s),
            "w2": _nrm(ks[3], (_LORA_W, d), 0.02),
            "u": _nrm(ks[4], (H, hd), 0.5),  # bonus ("time_faaaa")
            "wr": _nrm(ks[5], (d, d), s),
            "wk": _nrm(ks[6], (d, d), s),
            "wv": _nrm(ks[7], (d, d), s),
            "wg": _nrm(ks[8], (d, d), s),
            "wo": _nrm(ks[9], (d, d), s),
            "ln_x": {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)},
        },
        "cm": {
            "mu_k": jnp.zeros((d,), jnp.float32),
            "mu_r": jnp.zeros((d,), jnp.float32),
            "wk": _nrm(ks[10], (d, cfg.d_ff), s),
            "wv": _nrm(ks[11], (cfg.d_ff, d), 1.0 / np.sqrt(cfg.d_ff)),
            "wr": _nrm(ks[10], (d, d), s),
        },
    }


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    H, hd = cfg.n_heads, cfg.d_head
    return {
        "shift_tm": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
        "shift_cm": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.compute_dtype)),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


# ----------------------------------------------------------------- recurrence
def wkv_scan_ref(r, k, v, lw, u, s0):
    """Exact per-token scan (oracle + decode path).

    r,k,v,lw: (B,T,H,hd)   lw = log decay (<= 0)
    u: (H,hd)   s0: (B,H,hd,hd)  ->  y: (B,T,H,hd), sT: (B,H,hd,hd)
    """
    rf, kf, vf, lwf = (a.astype(jnp.float32) for a in (r, k, v, lw))

    def step(S, inp):
        rt, kt, vt, lwt = inp  # (B,H,hd)
        akv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * akv)
        S = jnp.exp(lwt)[..., :, None] * S + akv
        return S, yt

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, lwf))
    sT, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), sT


def wkv_chunked(r, k, v, lw, u, s0, chunk: int = 32, unroll: bool = False):
    """Exact chunked form: scan over T/L chunks, matmuls within a chunk.

    Stability: with c = within-chunk cumsum of lw (c <= 0, decreasing),
      inter:  y += (r_t ⊙ e^{c_{t-1}}) · S_chunk          (exponent <= 0)
      intra:  score_{ts} = Σ_i r_t k_s e^{c_{t-1}-c_s}, s<t  (exponent <= 0)
      state:  S' = e^{c_L} ⊙ S + Σ_s (k_s e^{c_L - c_s}) v_sᵀ (exponent <= 0)
    """
    B, T, H, hd = r.shape
    L = min(chunk, T)
    if T % L:
        raise ValueError(f"T={T} not divisible by rec_chunk={L}")
    nc = T // L
    rf, kf, vf, lwf = (
        a.astype(jnp.float32).reshape(B, nc, L, H, hd).transpose(1, 0, 3, 2, 4)
        for a in (r, k, v, lw)
    )  # (nc, B, H, L, hd)

    c = jnp.cumsum(lwf, axis=-2)  # (nc,B,H,L,hd)
    q_dec = rf * jnp.exp(c - lwf)  # r_t e^{c_{t-1}}
    k_end = kf * jnp.exp(c[..., -1:, :] - c)  # k_s e^{c_L - c_s}
    # intra-chunk pairwise scores (exact log-space differences, s<t)
    expo = (c - lwf)[..., :, None, :] - c[..., None, :, :]  # (nc,B,H,L,L,hd)
    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)[None, None, None]
    ew = jnp.where(tri[..., None], jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
    scores = jnp.einsum("nbhtsi,nbhti,nbhsi->nbhts", ew, rf, kf)
    diag = jnp.einsum("nbhti,hi,nbhti->nbht", rf, u.astype(jnp.float32), kf)
    ii = jnp.arange(L)
    scores = scores.at[..., ii, ii].add(diag)
    y_intra = jnp.einsum("nbhts,nbhsv->nbhtv", scores, vf)

    def body(S, xs):
        q_dec_c, k_end_c, v_c, y_in_c, c_last = xs
        y = y_in_c + jnp.einsum("bhti,bhiv->bhtv", q_dec_c, S)
        S = jnp.exp(c_last)[..., None] * S + jnp.einsum("bhsi,bhsv->bhiv", k_end_c, v_c)
        return S, y

    xs = (q_dec, k_end, vf, y_intra, c[..., -1, :])
    if unroll:
        # python loop over chunks: exact cost_analysis (no while-loop body
        # undercounting) — used by the dry-run
        S, ys_l = s0, []
        for n in range(nc):
            S, yn = body(S, jax.tree.map(lambda a: a[n], xs))
            ys_l.append(yn)
        sT, ys = S, jnp.stack(ys_l, axis=0)
    else:
        sT, ys = jax.lax.scan(body, s0, xs)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, hd)
    return y.astype(r.dtype), sT


# ----------------------------------------------------------------- the block
def _ddlerp(x, x_prev, tm):
    """Data-dependent token-shift interpolation (Finch §3)."""
    dx = x_prev - x
    xxx = x + dx * tm["mu_x"].astype(x.dtype)
    z = jnp.tanh(xxx @ tm["mix_w1"].astype(x.dtype))  # (B,T,5*R)
    B, T = x.shape[:2]
    z = z.reshape(B, T, 5, _LORA_MIX)
    deltas = jnp.einsum("btfr,frd->btfd", z, tm["mix_w2"].astype(x.dtype))
    mixed = x[:, :, None, :] + dx[:, :, None, :] * (
        tm["mu_5"].astype(x.dtype)[None, None] + deltas
    )
    return tuple(mixed[:, :, i] for i in range(5))  # xw, xk, xv, xr, xg


def rwkv_apply(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    state: dict,
    *,
    exec_cfg: ExecConfig = ExecConfig(),
) -> Tuple[jnp.ndarray, dict]:
    """Full block: time-mix (+residual) then channel-mix (+residual).
    x: (B,T,D).  ``state`` carries shift tokens + wkv state across calls
    (T=1 decode works through the same code path via the ref scan)."""
    B, T, D = x.shape
    H, hd = cfg.n_heads, cfg.d_head
    dt = x.dtype
    tm, cm = p["tm"], p["cm"]

    # ---- time mix (pre-LN stream carries the token shift) -------------------
    xn = norm_apply("layernorm", p["ln1"], x)
    xs = jnp.concatenate([state["shift_tm"][:, None, :], xn[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(xn, xs, tm)
    r = (xr @ tm["wr"].astype(dt)).reshape(B, T, H, hd)
    k = (xk @ tm["wk"].astype(dt)).reshape(B, T, H, hd)
    v = (xv @ tm["wv"].astype(dt)).reshape(B, T, H, hd)
    g = xg @ tm["wg"].astype(dt)
    wlog = tm["w0"].astype(jnp.float32) + jnp.tanh(
        xw.astype(jnp.float32) @ tm["w1"].astype(jnp.float32)
    ) @ tm["w2"].astype(jnp.float32)
    lw = -jnp.exp(wlog).reshape(B, T, H, hd)  # log decay <= 0

    if T == 1 or exec_cfg.rec_chunk <= 1 or T % min(exec_cfg.rec_chunk, T):
        y, sT = wkv_scan_ref(r, k, v, lw, tm["u"], state["wkv"])
    elif exec_cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops

        y, sT = kops.rwkv_scan(
            r, k, v, lw, tm["u"], state["wkv"],
            chunk=exec_cfg.rec_chunk, interpret=exec_cfg.interpret,
        )
    else:
        y, sT = wkv_chunked(
            r, k, v, lw, tm["u"], state["wkv"],
            chunk=exec_cfg.rec_chunk, unroll=exec_cfg.rec_unroll,
        )

    # per-head groupnorm, gate, out-proj
    yf = y.reshape(B, T, H, hd)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = ((yf - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, T, D)
    yn = yn * tm["ln_x"]["scale"].astype(dt) + tm["ln_x"]["bias"].astype(dt)
    out_tm = (yn * jax.nn.silu(g)) @ tm["wo"].astype(dt)
    x = x + out_tm
    new_state = {"shift_tm": xn[:, -1], "wkv": sT}

    # ---- channel mix (its own pre-LN stream) ---------------------------------
    xn2 = norm_apply("layernorm", p["ln2"], x)
    xs2 = jnp.concatenate([state["shift_cm"][:, None, :], xn2[:, :-1]], axis=1)
    dx2 = xs2 - xn2
    xk2 = xn2 + dx2 * cm["mu_k"].astype(dt)
    xr2 = xn2 + dx2 * cm["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk2 @ cm["wk"].astype(dt)))
    out_cm = jax.nn.sigmoid(xr2 @ cm["wr"].astype(dt)) * (kk @ cm["wv"].astype(dt))
    y_out = x + out_cm
    new_state["shift_cm"] = xn2[:, -1]
    return y_out, new_state
