"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (elementwise, per channel):

    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    a_t = exp(c * r_t * log(sigmoid(Λ)))    # data-dependent decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) (i_t ⊙ x_t)

The block (Griffin "recurrent block"): two branches from the pre-norm input —
(a) linear→GeLU and (b) linear→causal depthwise conv1d(width 4)→RG-LRU —
merged multiplicatively and projected back.  Sequence processing uses
``jax.lax.associative_scan`` (O(log T) depth; exact); decode is a single
elementwise step.  The chunked Pallas kernel implements the same first-order
scan with VMEM-resident carry.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ExecConfig, ModelConfig
from .layers import _nrm

__all__ = ["rglru_init", "rglru_apply", "init_rglru_state", "lru_scan_ref", "lru_scan"]

_C = 8.0  # Griffin's fixed decay sharpness


def rglru_init(rng, cfg: ModelConfig) -> dict:
    d, dr = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(rng, 6)
    s = 1.0 / np.sqrt(d)
    # Λ init so that a ∈ [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.sqrt(u) / (1.0 - jnp.sqrt(u)))  # logit of a^(1/c)... see note
    return {
        "wx_gelu": _nrm(ks[1], (d, dr), s),  # branch (a)
        "wx_rec": _nrm(ks[2], (d, dr), s),  # branch (b)
        "conv_w": _nrm(ks[3], (cfg.conv_width, dr), 0.1),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "wa": _nrm(ks[4], (dr, dr), 1.0 / np.sqrt(dr)),
        "ba": jnp.zeros((dr,), jnp.float32),
        "wi": _nrm(ks[5], (dr, dr), 1.0 / np.sqrt(dr)),
        "bi": jnp.zeros((dr,), jnp.float32),
        "lam": lam,
        "wo": _nrm(ks[0], (dr, d), 1.0 / np.sqrt(dr)),
    }


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    dr = cfg.rnn_width
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), jnp.dtype(cfg.compute_dtype)),
    }


# ----------------------------------------------------------------- recurrence
def lru_scan_ref(a, b, h0):
    """Exact per-token scan: h_t = a_t h_{t-1} + b_t.  a,b: (B,T,D)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0))
    hT, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1), hT


def lru_scan(a, b, h0):
    """associative_scan form of the same first-order recurrence (train path).
    Fold h0 into the first step: b_0' = a_0 h0 + b_0."""
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    aa, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hs, hs[:, -1]


# ----------------------------------------------------------------- the block
def _causal_conv1d(x, w, b, state):
    """Depthwise causal conv. x: (B,T,D), w: (W,D); state: (B,W-1,D) history."""
    W = w.shape[0]
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, T+W-1, D)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    return y + b.astype(x.dtype), xp[:, -(W - 1) :]


def rglru_apply(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    state: dict,
    *,
    exec_cfg: ExecConfig = ExecConfig(),
) -> Tuple[jnp.ndarray, dict]:
    """Temporal-mix half of the Griffin block (residual handled by caller).
    x: (B,T,D) pre-normed. Returns (out (B,T,D), new_state)."""
    dt = x.dtype
    ga = jax.nn.gelu(x @ p["wx_gelu"].astype(dt))  # branch (a)
    xb = x @ p["wx_rec"].astype(dt)  # branch (b)
    xb, new_conv = _causal_conv1d(xb, p["conv_w"], p["conv_b"], state["conv"])

    # RG-LRU gates (fp32 for the recurrence)
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a_base = -jax.nn.softplus(-p["lam"])  # log sigmoid(Λ)  (<= 0)
    log_a = _C * r * log_a_base[None, None, :]
    a = jnp.exp(log_a)
    gated = i * xf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if x.shape[1] == 1:
        h = a[:, 0] * state["h"] + b[:, 0]
        hs, hT = h[:, None], h
    elif exec_cfg.attn_impl == "pallas" and x.shape[1] % max(exec_cfg.rec_chunk, 1) == 0:
        from repro.kernels import ops as kops

        hs, hT = kops.lru_scan(
            a, b, state["h"], chunk=exec_cfg.rec_chunk, interpret=exec_cfg.interpret
        )
    else:
        hs, hT = lru_scan(a, b, state["h"])

    out = (hs.astype(dt) * ga) @ p["wo"].astype(dt)
    return out, {"h": hT, "conv": new_conv}
