"""Shared layers: norms, RoPE, FFNs, embeddings — pure JAX, init + apply."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "norm_init",
    "norm_apply",
    "linear_init",
    "linear_apply",
    "rope_apply",
    "ffn_init",
    "ffn_apply",
    "embed_init",
]


def _nrm(rng, shape, scale):
    return scale * jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)


# ------------------------------------------------------------------- norms
def norm_init(kind: str, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(kind: str, p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dt)


# ------------------------------------------------------------------ linear
def linear_init(rng, d_in: int, d_out: int, *, bias: bool = False, scale: float = None) -> dict:
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    p = {"w": _nrm(rng, (d_in, d_out), scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear_apply(p: dict, x: jnp.ndarray, dtype=None) -> jnp.ndarray:
    dtype = dtype or x.dtype
    y = x @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


# -------------------------------------------------------------------- RoPE
def rope_apply(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (S,) int32.  On-the-fly cos/sin (no
    table — needed for 500k-position decode)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(dt)


# --------------------------------------------------------------------- FFN
def ffn_init(rng, kind: str, d: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "wi": _nrm(k1, (d, d_ff), 1.0 / np.sqrt(d)),
            "wg": _nrm(k2, (d, d_ff), 1.0 / np.sqrt(d)),
            "wo": _nrm(k3, (d_ff, d), 1.0 / np.sqrt(d_ff)),
        }
    if kind == "gelu":
        return {
            "wi": _nrm(k1, (d, d_ff), 1.0 / np.sqrt(d)),
            "bi": jnp.zeros((d_ff,), jnp.float32),
            "wo": _nrm(k3, (d_ff, d), 1.0 / np.sqrt(d_ff)),
            "bo": jnp.zeros((d,), jnp.float32),
        }
    raise ValueError(f"unknown ffn kind {kind}")


def ffn_apply(kind: str, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
        return h @ p["wo"].astype(dt)
    if kind == "gelu":
        h = jax.nn.gelu(x @ p["wi"].astype(dt) + p["bi"].astype(dt))
        return h @ p["wo"].astype(dt) + p["bo"].astype(dt)
    raise ValueError(f"unknown ffn kind {kind}")


# --------------------------------------------------------------- embedding
def embed_init(rng, vocab: int, d: int) -> dict:
    return {"table": _nrm(rng, (vocab, d), 1.0)}
