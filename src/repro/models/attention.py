"""Attention: GQA/MQA/MHA, causal + sliding-window + cross, KV cache.

Cache layout (per attention instance):
    {"k": (B, Kh, W, hd), "v": (B, Kh, W, hd), "pos": (W,) int32}
``W`` = window size for local-attention layers (ring buffer) else max
sequence length.  ``pos`` holds the absolute position stored in each slot
(-1 = empty), which drives causal/window masking uniformly across train /
prefill / decode.  Batched serving advances all rows in lockstep (one shared
position per step) — the standard batched-decode regime.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ExecConfig, ModelConfig
from .layers import linear_apply, linear_init, rope_apply

__all__ = ["attn_init", "attn_apply", "init_attn_cache", "cross_kv"]

NEG_INF = -1e30


def attn_init(rng, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.d_head
    kq, kk, kv, ko = jax.random.split(rng, 4)
    return {
        "wq": linear_init(kq, d, cfg.n_heads * hd, bias=cfg.qkv_bias),
        "wk": linear_init(kk, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": linear_init(kv, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": linear_init(ko, cfg.n_heads * hd, d),
    }


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, *, window: int = 0) -> dict:
    w = min(window, max_len) if window > 0 else max_len
    shape = (batch, cfg.n_kv_heads, w, cfg.d_head)
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "pos": jnp.full((w,), -1, jnp.int32),
    }


def _mask(q_pos, kv_pos, *, causal: bool, window: int):
    """(Sq, Skv) bool validity mask from absolute positions."""
    valid = kv_pos[None, :] >= 0
    if causal:
        valid &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        valid &= q_pos[:, None] - kv_pos[None, :] < window
    return valid


def _sdpa(q, k, v, mask) -> jnp.ndarray:
    """q: (B,Sq,H,hd), k/v: (B,Kh,Skv,hd), mask: (Sq,Skv) -> (B,Sq,H,hd).
    fp32 softmax; GQA via head grouping."""
    # NOTE (§Perf iteration 1, REFUTED hypothesis): explicit sharding
    # constraints on the S² chain were tried here and changed nothing — A/B
    # showed GSPMD already shards scores over (dp × heads); the term is big
    # because S² itself is big.  The real fix is the flash kernel
    # (kernels/flash_attention.py); see "flashcost" below for how the
    # dry-run accounts for it.
    B, Sq, H, hd = q.shape
    Kh = k.shape[1]
    g = H // Kh
    qh = q.reshape(B, Sq, Kh, g, hd)
    scores = jnp.einsum("bqkgh,bksh->bkgqs", qh, k, preferred_element_type=jnp.float32)
    scores = scores * (1.0 / np.sqrt(hd))
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bksh->bqkgh", p, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_flashcost(q, k, v) -> jnp.ndarray:
    """Kernel-cost surrogate for dry-run lowering (attn_impl='flashcost').

    Pallas cannot lower without a TPU, so §Perf candidates that run the flash
    kernel lower THIS surrogate instead: it reads Q/K/V once and writes O
    once — exactly the kernel's HBM traffic (the S² tile lives in VMEM) —
    while the kernel's MXU FLOPs are re-added analytically
    (costing.attention_traffic / flash_flops).  Not a numerics path: only
    lowered for cost accounting.
    """
    B, Sq, H, hd = q.shape
    Kh = k.shape[1]
    mk = jnp.mean(k, axis=2)  # (B,Kh,hd): touches all of K
    mv = jnp.mean(v, axis=2)
    g = H // Kh
    mk = jnp.repeat(mk, g, axis=1)[:, None]  # (B,1,H,hd)
    mv = jnp.repeat(mv, g, axis=1)[:, None]
    return q * mk + mv


def _sdpa_flash(q, k, v, *, causal: bool, exec_cfg: ExecConfig) -> jnp.ndarray:
    """Pallas flash-attention path (train/prefill, no cache, full positions)."""
    from repro.kernels import ops as kops

    return kops.flash_attention(
        q,
        k,
        v,
        causal=causal,
        block_q=exec_cfg.block_q,
        block_kv=exec_cfg.block_kv,
        interpret=exec_cfg.interpret,
    )


def cross_kv(cfg: ModelConfig, p: dict, ctx: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute cross-attention K/V from context embeddings (B,N,D)."""
    B, N, _ = ctx.shape
    k = linear_apply(p["wk"], ctx).reshape(B, N, cfg.n_kv_heads, cfg.d_head)
    v = linear_apply(p["wv"], ctx).reshape(B, N, cfg.n_kv_heads, cfg.d_head)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def attn_apply(
    cfg: ModelConfig,
    p: dict,
    x: jnp.ndarray,
    *,
    q_pos: jnp.ndarray,  # (Sq,) absolute positions of the query tokens
    cache: Optional[dict] = None,
    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn K/V
    causal: bool = True,
    window: int = 0,
    rope: bool = True,
    exec_cfg: ExecConfig = ExecConfig(),
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Self- or cross-attention with optional KV cache.

    Modes:
      * train/encode: ``cache=None, kv=None`` — full-sequence self-attention.
      * prefill:      ``cache=empty`` — fills the cache, returns outputs.
      * decode:       ``cache=filled``, Sq=1 — appends one step.
      * cross:        ``kv=(k,v)`` precomputed from context; no cache update.
    """
    B, Sq, D = x.shape
    H, Kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = linear_apply(p["wq"], x).reshape(B, Sq, H, hd)

    if kv is not None:  # ---------------------------------------- cross-attn
        k, v = kv
        if exec_cfg.attn_impl == "flashcost":
            out = _sdpa_flashcost(q, k, v)
        else:
            mask = jnp.ones((Sq, k.shape[2]), bool)
            out = _sdpa(q, k, v, mask)
        return linear_apply(p["wo"], out.reshape(B, Sq, H * hd)), cache

    kc = linear_apply(p["wk"], x).reshape(B, Sq, Kh, hd)
    vc = linear_apply(p["wv"], x).reshape(B, Sq, Kh, hd)
    if rope:
        q = rope_apply(q, q_pos, cfg.rope_theta)
        kc = rope_apply(kc, q_pos, cfg.rope_theta)
    kc = kc.transpose(0, 2, 1, 3)  # (B,Kh,Sq,hd)
    vc = vc.transpose(0, 2, 1, 3)

    if cache is None:  # ------------------------------------- train / encode
        if exec_cfg.attn_impl == "pallas" and window == 0:
            out = _sdpa_flash(q, kc, vc, causal=causal, exec_cfg=exec_cfg)
        elif exec_cfg.attn_impl == "flashcost":
            out = _sdpa_flashcost(q, kc, vc)
        else:
            mask = _mask(q_pos, q_pos, causal=causal, window=window)
            out = _sdpa(q, kc, vc, mask)
        return linear_apply(p["wo"], out.reshape(B, Sq, H * hd)), None

    # ------------------------------------------------- prefill / decode step
    W = cache["k"].shape[2]
    if Sq > 1:
        # prefill: attend over the in-flight full sequence (correct even when
        # Sq > W), then persist only the last W entries into the ring.
        if exec_cfg.attn_impl == "flashcost":
            out = _sdpa_flashcost(q, kc, vc)
        else:
            mask = _mask(q_pos, q_pos, causal=causal, window=window)
            out = _sdpa(q, kc, vc, mask)
        if Sq > W:
            kc, vc, q_pos = kc[:, :, Sq - W :], vc[:, :, Sq - W :], q_pos[Sq - W :]
        slots = jnp.mod(q_pos, W)
        new_cache = {
            "k": cache["k"].at[:, :, slots].set(kc),
            "v": cache["v"].at[:, :, slots].set(vc),
            "pos": cache["pos"].at[slots].set(q_pos.astype(jnp.int32)),
        }
        return linear_apply(p["wo"], out.reshape(B, Sq, H * hd)), new_cache

    # decode: append one step into the ring, attend over the cache
    slots = jnp.mod(q_pos, W)
    new_cache = {
        "k": cache["k"].at[:, :, slots].set(kc),
        "v": cache["v"].at[:, :, slots].set(vc),
        "pos": cache["pos"].at[slots].set(q_pos.astype(jnp.int32)),
    }
    if exec_cfg.attn_impl == "flashcost":
        out = _sdpa_flashcost(q, new_cache["k"], new_cache["v"])
    else:
        mask = _mask(q_pos, new_cache["pos"], causal=causal, window=window)
        out = _sdpa(q, new_cache["k"], new_cache["v"], mask)
    return linear_apply(p["wo"], out.reshape(B, Sq, H * hd)), new_cache
