"""Mixture-of-Experts FFN: top-k routing, capacity-factor scatter dispatch.

GShard-style capacity dispatch expressed with scatter/gather (not the giant
(T,E,C) one-hot einsum — the scatter form keeps the dispatch buffer at
(E, C, D), which shards as E→model (EP), C→data).  Tokens beyond an expert's
capacity are dropped (standard).  ``capacity_factor`` is a PATSMA-tunable.

Arctic variant (``moe_dense_residual``): a dense SwiGLU FFN runs in parallel
with the MoE and the outputs add (Snowflake Arctic's dense-MoE hybrid).
Router aux loss (Switch load-balance) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _nrm, ffn_apply, ffn_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(rng, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    kr, ki, kg, ko, kd = jax.random.split(rng, 5)
    p = {
        "router": _nrm(kr, (d, e), 1.0 / np.sqrt(d)),
        "wi": _nrm(ki, (e, d, f), 1.0 / np.sqrt(d)),
        "wg": _nrm(kg, (e, d, f), 1.0 / np.sqrt(d)),
        "wo": _nrm(ko, (e, f, d), 1.0 / np.sqrt(f)),
    }
    if cfg.moe_dense_residual:
        p["dense"] = ffn_init(kd, "swiglu", d, cfg.d_ff)
    return p


def moe_apply(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    dt = x.dtype
    xt = x.reshape(T, D)

    # ---- routing (fp32) ----------------------------------------------------
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)  # (T,K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # Switch load-balance aux loss: E * mean(f_e * P_e)
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = E * jnp.sum(me * ce)

    # ---- capacity + position within expert ---------------------------------
    # Decode/small batches run drop-free (serving must not drop tokens); large
    # token counts use the standard capacity factor (PATSMA-tunable).
    C = int(np.ceil(T * K / E * cfg.capacity_factor))
    if T * K <= 8192:
        C = T * K
    flat_e = eidx.reshape(T * K)  # assignment order: token-major, slot-minor
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # running count per expert
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T*K,)
    keep = pos_in_e < C
    slot = jnp.minimum(pos_in_e, C - 1)

    # ---- dispatch: scatter tokens into (E, C, D) ----------------------------
    from repro.parallel.api import constrain

    xr = jnp.repeat(xt[:, None, :], K, axis=1).reshape(T * K, D)
    contrib = jnp.where(keep[:, None], xr, jnp.zeros_like(xr))
    buf = jnp.zeros((E, C, D), dt).at[flat_e, slot].add(contrib)
    buf = constrain(buf, ("ep", "dp", None))  # EP: experts over model axis

    # ---- expert FFN (SwiGLU), batched over E --------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dt))) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wi"].astype(dt)
    )
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))  # (E,C,D)

    # ---- combine: gather back + weighted sum over K -------------------------
    yr = out[flat_e, slot]  # (T*K, D)
    yr = yr * (gate.reshape(T * K, 1).astype(dt) * keep[:, None].astype(dt))
    y = jnp.sum(yr.reshape(T, K, D), axis=1)

    if cfg.moe_dense_residual:
        y = y + ffn_apply("swiglu", p["dense"], xt)
    return y.reshape(B, S, D), aux
