"""repro.models — the architecture substrate shared by all 10 assigned archs."""
from .config import ExecConfig, ModelConfig
from .model import Model

__all__ = ["Model", "ModelConfig", "ExecConfig"]
