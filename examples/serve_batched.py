"""Batched serving example: prefill a batch of prompts, decode with a KV
cache, and let PATSMA (Single-Iteration mode) tune the decode fusion depth —
how many tokens each jitted multi-step decode call emits (dispatch overhead
vs scheduling granularity: the classic serving knob).

    PYTHONPATH=src python examples/serve_batched.py
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import Autotuning, CSA, ChoiceDim, SearchSpace
from repro.models import ExecConfig, Model


def make_multi_decode(model, k: int):
    """One jitted call emitting k greedy tokens."""

    @jax.jit
    def run(params, token, states, pos):
        def body(carry, _):
            token, states, pos = carry
            logits, states = model.decode_step(params, token, states, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return (nxt, states, pos + 1), nxt

        (token, states, pos), toks = jax.lax.scan(body, (token, states, pos), None, length=k)
        return token, states, pos, toks

    return run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=192)
    ap.add_argument("--arch", type=str, default="qwen2_7b")
    args = ap.parse_args()

    cfg = configs.get_tiny(args.arch)
    model = Model(cfg, ExecConfig(rec_chunk=4))
    params = model.init(jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)

    t0 = time.perf_counter()
    hidden, states = model.prefill(params, {"tokens": prompts, "max_len": max_len})
    logits = model.logits(params, hidden[:, None])[:, 0]
    token = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(token)
    print(f"prefill {B}x{P}: {(time.perf_counter()-t0)*1e3:.0f} ms")

    # PATSMA rides the serving loop: each tuning iteration = one decode call
    space = SearchSpace([ChoiceDim("k", (1, 2, 4, 8, 16))])
    at = Autotuning(space=space, ignore=1,
                    search=CSA(1, num_opt=3, max_iter=5, seed=0), cache=True)
    decoders = {}
    pos = jnp.int32(P)
    emitted = 0
    calls = 0
    t0 = time.perf_counter()
    while emitted < args.gen:
        k = at.point["k"]
        k = min(k, args.gen - emitted)
        fn = decoders.setdefault(k, make_multi_decode(model, k))
        tc = time.perf_counter()
        token, states, pos, toks = fn(params, token, states, pos)
        jax.block_until_ready(toks)
        at.exec((time.perf_counter() - tc) / k)  # cost = seconds PER TOKEN
        emitted += k
        calls += 1
    wall = time.perf_counter() - t0
    print(f"decoded {emitted} tokens/seq x {B} seqs in {wall*1e3:.0f} ms "
          f"({B*emitted/wall:.0f} tok/s) over {calls} calls")
    print("tuned decode fusion depth k =", at.best_point["k"],
          f"(tuning finished: {at.finished})")


if __name__ == "__main__":
    main()
