"""End-to-end training driver example: ~100M-param decoder LM, a few hundred
steps on CPU, with every production substrate live: synthetic data pipeline,
AdamW + cosine schedule, async atomic checkpointing (resume works — kill it
and rerun), PATSMA Single-Iteration tuning of the microbatch knob riding the
loop, and the straggler watchdog.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 200
    PYTHONPATH=src python examples/train_tiny_lm.py --quick   # 30 steps, smaller model
"""
import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import ChoiceDim, SearchSpace, TunedStep
from repro.data import SyntheticLM
from repro.models import ExecConfig, Model, ModelConfig
from repro.optim import AdamW, cosine_schedule
from repro.runtime.driver import Watchdog
from repro.train import make_train_step


def lm100m() -> ModelConfig:
    """~100M params: 12L, d=768, 12H, ff=3072, vocab 8192 (GQA kv=4)."""
    return ModelConfig(
        name="lm100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=3072, vocab_size=8192, rope_theta=10_000.0,
        vocab_pad_multiple=16,
    )


def lm10m() -> ModelConfig:
    return ModelConfig(
        name="lm10m", family="dense", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=1024, vocab_size=4096, vocab_pad_multiple=16,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_lm100m")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-tune", action="store_true")
    args = ap.parse_args()

    cfg = lm10m() if args.quick else lm100m()
    if args.quick:
        args.steps = min(args.steps, 30)
    model = Model(cfg, ExecConfig())
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    opt = AdamW(lr=cosine_schedule(args.lr, warmup=20, total=args.steps))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=1)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if ckpt.latest_step() is not None:
        (params, opt_state), start, extra = ckpt.restore((params, opt_state))
        start += 1
        print(f"resumed from step {start - 1} (loss was {extra.get('loss')})")

    def factory(microbatches=1):
        return jax.jit(make_train_step(model, opt, microbatches=microbatches),
                       donate_argnums=(0, 1))

    if args.no_tune:
        tuned = None
        step_fn = factory(1)
    else:
        mbs = tuple(m for m in (1, 2, 4) if args.batch % m == 0)
        tuned = TunedStep(
            factory, SearchSpace([ChoiceDim("microbatches", mbs)]),
            ignore=1, num_opt=3, max_iter=4, cache=True, seed=0,
        )

    wd = Watchdog()
    t_start = time.time()
    for step in range(start, args.steps):
        batch = data.batch(step)
        t0 = time.perf_counter()
        if tuned is not None:
            params, opt_state, m = tuned(params, opt_state, batch)
        else:
            params, opt_state, m = step_fn(params, opt_state, batch)
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        wd.check(dt, step)
        if step % 10 == 0 or step == args.steps - 1:
            knobs = "" if tuned is None else f" knobs={tuned.knobs}"
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f} {dt*1e3:6.0f} ms{knobs}")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step, (params, opt_state),
                            extra={"loss": float(m["loss"])})
    ckpt.wait()
    ckpt.save(args.steps - 1, (params, opt_state), extra={"loss": float(m["loss"])})
    wall = time.time() - t_start
    print(f"done: {args.steps - start} steps in {wall:.0f}s "
          f"({(args.steps-start)/wall:.2f} steps/s); watchdog events: {len(wd.events)}")
    if tuned is not None:
        print("final tuned knobs:", tuned.best_knobs)
    with open(os.path.join(args.ckpt_dir, "history.json"), "w") as f:
        json.dump({"final_loss": float(m["loss"]), "steps": args.steps}, f)


if __name__ == "__main__":
    main()
