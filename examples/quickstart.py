"""PATSMA quickstart: the paper's API in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import Autotuning, CSA, LogIntDim, SearchSpace

# ---- 1. plain staged optimization (paper §2.4 exec mode) -------------------
at = Autotuning(min=-20, max=20, ignore=0, dim=2, num_opt=4, max_iter=25, seed=0)
p = at.point
while not at.finished:
    cost = (p["p0"] - 7) ** 2 + (p["p1"] + 3) ** 2  # the app computes its own cost
    p = at.exec(cost)
print("exec-mode optimum:", at.best_point)  # -> {'p0': 7, 'p1': -3}

# ---- 2. Runtime mode: tune a jitted function's block size ------------------
x = jnp.ones((512, 512))


def make_fn(block):  # smaller blocks do redundant passes — a runtime knob
    @jax.jit
    def fn(x):
        acc = x
        for _ in range(512 // block):
            acc = acc + jnp.tanh(x)
        return acc

    return fn


fns = {}
at = Autotuning(space=SearchSpace([LogIntDim("block", 32, 512)]),
                ignore=1,  # first call per candidate absorbs XLA compile
                search=CSA(1, num_opt=4, max_iter=6, seed=0), cache=True)
while not at.finished:
    knobs = at.start()  # paper start()/end() runtime brackets
    fn = fns.setdefault(knobs["block"], make_fn(knobs["block"]))
    out = fn(x)
    at.end(out)  # blocks on the result, measures wall time
print("runtime-mode block size:", at.best_point, f"({at.num_measurements} measurements)")
