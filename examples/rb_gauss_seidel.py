"""The paper's illustrative example (§3): Red-Black Gauss-Seidel with the
parallel chunk auto-tuned — Algorithms 5 (entire) and 6 (single) side by side.

    PYTHONPATH=src python examples/rb_gauss_seidel.py
"""
import sys

sys.path.insert(0, ".")
from benchmarks.rb_gauss_seidel import run

if __name__ == "__main__":
    out = run(n=256, iters=40)
    print("\nsummary:")
    print(" exhaustive best block:", out["best_truth"])
    print(" CSA entire-execution :", out["csa_entire"]["point"],
          f"({out['csa_entire']['measurements']} replica sweeps)")
    print(" NM  entire-execution :", out["nm_entire"]["point"])
    print(" CSA single-iteration : overhead",
          f"{out['csa_single']['overhead_pct']:.1f}% vs oracle")
